"""Lock tracking: named locks, a lock-order graph, held-lock contracts.

The serving daemon's thread safety rests on about a dozen
``threading.Lock``/``RLock``/``Condition`` sites whose invariants --
"synthesis happens outside the cache lock", "the monitor notifies in
version order under its lock", "the server lock is a leaf" -- used to
live only in docstrings.  This module turns them into machine-checked
contracts:

  * Every lock in ``serving/`` and ``core/`` is created through a *named
    factory* (``make_lock``/``make_rlock``/``make_condition``).  With
    analysis off (the default) the factories return plain ``threading``
    primitives -- zero overhead, bit-for-bit the old behavior.  With
    ``REPRO_LOCK_ANALYSIS=1`` (or ``enable()``) they return tracked
    wrappers that record, per thread, the order in which named locks are
    acquired while other named locks are held.

  * The recorded edges form the process-global **lock-order graph**
    (``lock_order_edges``).  A cycle in that graph is a potential
    deadlock: two threads can interleave the cyclic acquisitions and
    block each other forever.  ``find_cycles``/``assert_acyclic`` make
    "the serving layer cannot deadlock" a test assertion instead of a
    review argument.

  * ``FORBIDDEN_WHILE_HELD`` declares which operations must never run
    while a given lock is held -- above all, no Birkhoff decomposition or
    plan synthesis inside ``PlanCache._lock`` or ``PlanServer._lock``
    (the PR-6 invariant that keeps the serving fast path microseconds).
    Instrumented entry points call ``check_forbidden("<op>")``; with
    analysis enabled, a violation is recorded (and surfaced by
    ``violations()``/``assert_clean``) the moment the contract is broken,
    with the offending lock and thread named.

Locks are tracked by *name*, not by instance: two ``PlanTicket`` locks
share the node ``"PlanTicket._lock"``.  That is deliberate -- deadlock
potential is a property of the code paths (classes), and per-instance
nodes would make the graph unbounded in a long-running daemon.  The cost
is that a genuine same-class lock nesting would appear as a self-edge;
no code path in this repo nests same-named locks, and the self-edge
would (correctly) fail ``assert_acyclic`` if one appeared.

This module imports nothing from the rest of ``repro`` so that ``core``
and ``serving`` can depend on it without cycles.
"""

from __future__ import annotations

import os
import threading
from typing import Dict, List, NamedTuple, Optional, Tuple, Union

__all__ = [
    "TrackedLock",
    "TrackedRLock",
    "make_lock",
    "make_rlock",
    "make_condition",
    "check_forbidden",
    "enabled",
    "enable",
    "disable",
    "reset",
    "lock_order_edges",
    "find_cycles",
    "assert_acyclic",
    "violations",
    "assert_clean",
    "held_locks",
    "FORBIDDEN_WHILE_HELD",
    "LockViolation",
]


# Operations that must never run while the named lock is held.  The values
# are operation tags passed to ``check_forbidden`` by the instrumented
# entry points (core/birkhoff.birkhoff_decompose, Scheduler.synthesize):
# synthesis is the expensive path the serving layer explicitly moved
# *outside* its locks, and a regression that reintroduces it under a lock
# turns every concurrent cache hit into a multi-millisecond stall.
FORBIDDEN_WHILE_HELD: Dict[str, Tuple[str, ...]] = {
    "PlanCache._lock": ("birkhoff_decompose", "synthesize"),
    "PlanServer._lock": ("birkhoff_decompose", "synthesize"),
    "TieredQueue._lock": ("birkhoff_decompose", "synthesize"),
    "FabricMonitor._lock": ("birkhoff_decompose", "synthesize"),
}


class LockViolation(NamedTuple):
    """One recorded contract violation (see ``violations``)."""

    kind: str        # "forbidden_call"
    lock: str        # the held lock whose contract was broken
    operation: str   # the operation that ran while it was held
    thread: str      # name of the offending thread
    detail: str


_ENV_FLAG = "REPRO_LOCK_ANALYSIS"

# Tri-state override: None = follow the environment variable; True/False =
# forced by enable()/disable() (tests flip this without touching os.environ).
_override: Optional[bool] = None

# All module-global analysis state hangs off one *raw* lock -- the tracker
# itself must not be tracked.
_state_lock = threading.Lock()  # noqa: LCK001 -- the tracker's own lock
_edges: Dict[Tuple[str, str], int] = {}
_violations: List[LockViolation] = []

_tls = threading.local()


def enabled() -> bool:
    """Whether newly created locks are tracked and contracts checked."""
    if _override is not None:
        return _override
    return os.environ.get(_ENV_FLAG, "") not in ("", "0")


def enable() -> None:
    """Force analysis on for locks created from now on (tests)."""
    global _override
    _override = True


def disable() -> None:
    """Force analysis off (tests); ``reset`` clears recorded state."""
    global _override
    _override = False


def reset() -> None:
    """Drop every recorded edge and violation (not the held-lock stacks)."""
    with _state_lock:
        _edges.clear()
        del _violations[:]


def _held() -> List["_TrackedBase"]:
    stack = getattr(_tls, "held", None)
    if stack is None:
        stack = _tls.held = []
    return stack


def held_locks() -> Tuple[str, ...]:
    """Names of tracked locks the *current thread* holds, outermost first."""
    return tuple(lk.name for lk in _held())


class _TrackedBase:
    """Shared bookkeeping for tracked lock wrappers.

    Wraps a real ``threading`` primitive; every successful acquire pushes
    the wrapper onto the current thread's held stack and records a
    lock-order edge from each *distinct* already-held lock name to this
    one, and every release pops it.  The wrappers satisfy the subset of
    the lock protocol ``threading.Condition`` relies on (``acquire``,
    ``release``, context manager), so a condition built over a tracked
    lock keeps the bookkeeping exact across ``wait()``'s release/reacquire
    cycle.
    """

    __slots__ = ("name", "_inner")

    def __init__(self, name: str, inner):
        self.name = name
        self._inner = inner

    # -- bookkeeping -------------------------------------------------------

    def _reentrant(self) -> bool:
        return False

    def _note_acquired(self) -> None:
        stack = _held()
        if not (self._reentrant() and any(lk is self for lk in stack)):
            seen = set()
            new_edges = []
            for lk in stack:
                if lk.name != self.name and lk.name not in seen:
                    seen.add(lk.name)
                    new_edges.append((lk.name, self.name))
            if new_edges:
                with _state_lock:
                    for e in new_edges:
                        _edges[e] = _edges.get(e, 0) + 1
        stack.append(self)

    def _note_released(self) -> None:
        stack = _held()
        # Locks are almost always released LIFO; scan from the top so the
        # common case is O(1) while out-of-order release stays correct.
        for i in range(len(stack) - 1, -1, -1):
            if stack[i] is self:
                del stack[i]
                return

    def held_by_current_thread(self) -> bool:
        return any(lk is self for lk in _held())

    # -- lock protocol -----------------------------------------------------

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        ok = self._inner.acquire(blocking, timeout)
        if ok:
            self._note_acquired()
        return ok

    def release(self) -> None:
        self._note_released()
        self._inner.release()

    def __enter__(self) -> bool:
        return self.acquire()

    def __exit__(self, *exc) -> None:
        self.release()

    def __repr__(self) -> str:
        return f"<{type(self).__name__} {self.name!r} at {id(self):#x}>"


class TrackedLock(_TrackedBase):
    """Named, order-tracked ``threading.Lock`` (``make_lock``)."""

    __slots__ = ()

    def __init__(self, name: str):
        super().__init__(name, threading.Lock())  # noqa: LCK001 -- wrapped

    def locked(self) -> bool:
        return self._inner.locked()


class TrackedRLock(_TrackedBase):
    """Named, order-tracked ``threading.RLock`` (``make_rlock``).

    Reentrant re-acquisitions by the owning thread record no edges -- a
    lock cannot deadlock against itself through legitimate reentrancy.
    """

    __slots__ = ()

    def __init__(self, name: str):
        super().__init__(name, threading.RLock())  # noqa: LCK001 -- wrapped

    def _reentrant(self) -> bool:
        return True

    # threading.Condition uses these when handed an RLock-like object, so
    # a condition over a tracked RLock stays bookkeeping-exact.
    def _is_owned(self) -> bool:
        return self.held_by_current_thread()

    def _release_save(self):
        saved = self._inner._release_save()
        # The full recursion count was released in one call: drop every
        # stack entry for this lock.
        stack = _held()
        stack[:] = [lk for lk in stack if lk is not self]
        return saved

    def _acquire_restore(self, saved) -> None:
        self._inner._acquire_restore(saved)
        self._note_acquired()


def make_lock(name: str) -> Union[threading.Lock, TrackedLock]:
    """A mutex named for analysis: plain ``threading.Lock`` unless lock
    analysis is enabled (``REPRO_LOCK_ANALYSIS=1`` / ``enable()``), then a
    ``TrackedLock`` feeding the lock-order graph.  Name by owning class
    and attribute, e.g. ``"PlanCache._lock"``."""
    if enabled():
        return TrackedLock(name)
    return threading.Lock()  # noqa: LCK001 -- the factory itself


def make_rlock(name: str) -> Union[threading.RLock, TrackedRLock]:
    """``make_lock`` for reentrant locks."""
    if enabled():
        return TrackedRLock(name)
    return threading.RLock()  # noqa: LCK001 -- the factory itself


def make_condition(name: str, lock=None) -> threading.Condition:
    """A condition variable over a (tracked when enabled) named lock.

    Pass ``lock`` to share an existing factory-made lock (the TieredQueue
    pattern: one mutex, one condition); otherwise a fresh one named
    ``name`` is created.  The returned object is always a genuine
    ``threading.Condition`` -- over the tracked wrapper when analysis is
    on, so waits and notifications keep the held-lock bookkeeping exact.
    """
    if lock is None:
        lock = make_lock(name)
    return threading.Condition(lock)  # noqa: LCK001 -- the factory itself


def check_forbidden(operation: str) -> None:
    """Record a violation if ``operation`` runs under a forbidding lock.

    Instrumented entry points (``birkhoff_decompose``, ``synthesize``)
    call this unconditionally; with analysis disabled it is a single flag
    check.  Violations are recorded, not raised: the contract check must
    never alter control flow of the system under test -- tests assert via
    ``violations()``/``assert_clean`` afterwards.
    """
    if not enabled():
        return
    held = _held()
    if not held:
        return
    for lk in held:
        forbidden = FORBIDDEN_WHILE_HELD.get(lk.name, ())
        if operation in forbidden:
            v = LockViolation(
                kind="forbidden_call",
                lock=lk.name,
                operation=operation,
                thread=threading.current_thread().name,
                detail=(f"{operation!r} ran while {lk.name!r} was held "
                        f"(held stack: {list(held_locks())})"),
            )
            with _state_lock:
                _violations.append(v)


# -- reporting -------------------------------------------------------------

def lock_order_edges() -> Dict[Tuple[str, str], int]:
    """Copy of the recorded lock-order graph: (held, acquired) -> count."""
    with _state_lock:
        return dict(_edges)


def find_cycles() -> List[List[str]]:
    """Every elementary cycle-witness in the lock-order graph.

    Returns one representative path per back edge found by iterative DFS
    (``[a, b, ..., a]``); empty means the acquisition order is a partial
    order and the tracked locks cannot deadlock among themselves.
    """
    graph: Dict[str, List[str]] = {}
    for (a, b) in lock_order_edges():
        graph.setdefault(a, []).append(b)
        graph.setdefault(b, [])
    cycles: List[List[str]] = []
    color: Dict[str, int] = {}  # 0/absent = white, 1 = on stack, 2 = done
    for root in sorted(graph):
        if color.get(root):
            continue
        path: List[str] = []
        stack: List[Tuple[str, int]] = [(root, 0)]
        while stack:
            node, idx = stack.pop()
            if idx == 0:
                color[node] = 1
                path.append(node)
            nbrs = graph[node]
            advanced = False
            for j in range(idx, len(nbrs)):
                nxt = nbrs[j]
                c = color.get(nxt, 0)
                if c == 1:
                    cycles.append(path[path.index(nxt):] + [nxt])
                elif c == 0:
                    stack.append((node, j + 1))
                    stack.append((nxt, 0))
                    advanced = True
                    break
            if not advanced:
                color[node] = 2
                path.pop()
    return cycles


def assert_acyclic() -> None:
    """Raise ``AssertionError`` naming the cycle if the graph has one."""
    cycles = find_cycles()
    if cycles:
        raise AssertionError(
            f"lock-order graph has {len(cycles)} cycle(s) -- potential "
            f"deadlock: {cycles}")


def violations() -> List[LockViolation]:
    with _state_lock:
        return list(_violations)


def assert_clean() -> None:
    """Acyclic graph *and* zero contract violations, or AssertionError."""
    assert_acyclic()
    vs = violations()
    if vs:
        raise AssertionError(
            f"{len(vs)} lock-contract violation(s): "
            + "; ".join(v.detail for v in vs))


def report() -> Dict:
    """JSON-compatible summary for the analysis runner."""
    return {
        "enabled": enabled(),
        "edges": [{"held": a, "acquired": b, "count": c}
                  for (a, b), c in sorted(lock_order_edges().items())],
        "cycles": find_cycles(),
        "violations": [v._asdict() for v in violations()],
    }
