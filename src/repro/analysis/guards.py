"""Guarded-state registry: which shared attributes which lock protects.

The serving layer's classes each own one mutex and a set of attributes
that must only change under it.  Before this module that mapping lived in
comments ("all mutation happens under one lock"); here it is *data* --
one ``GuardSpec`` per class -- consumed by two enforcement modes:

  * **Dynamic** (``install()``): with lock analysis enabled, every
    registered class's ``__setattr__`` is wrapped to assert that the
    instance's guard lock is held by the writing thread.  Writes during
    ``__init__`` are exempt (the object is thread-private until its
    constructor returns -- the wrapper arms itself on constructor exit),
    and enforcement only bites when the guard lock is a tracked lock
    (``locks.make_lock`` under ``REPRO_LOCK_ANALYSIS=1``), so production
    runs pay nothing.  Violations are recorded, never raised: the checker
    must not perturb the system under test.

  * **Static** (``analysis/astlint.py`` rule LCK002): a registered
    attribute assigned or mutated outside a ``with self._lock`` block --
    lexically, in the class's own methods -- is flagged at lint time,
    no execution needed.  Methods named ``*_locked`` are exempt by
    convention: they document that the caller holds the guard.

The dynamic mode sees real ``setattr`` writes (scalar counters, swapped
references); the static rule additionally covers container mutation
(``self._inflight[k] = v``, ``self._inexact.add(k)``) that never goes
through ``setattr``.  Together they close the gap.

Registry hygiene: only attributes the guard genuinely covers belong
here.  Deliberately *unregistered* shared state is documented at the
spec, e.g. ``PlanServer._dying`` (keyed by thread ident, each entry
thread-private) and config attributes assigned once before any thread
can see the object.
"""

from __future__ import annotations

import dataclasses
import functools
import importlib
import threading
from typing import Dict, List, NamedTuple, Tuple

__all__ = [
    "GuardSpec",
    "REGISTRY",
    "install",
    "uninstall",
    "installed",
    "guard_violations",
    "reset_violations",
    "specs_by_class",
    "report",
]


@dataclasses.dataclass(frozen=True)
class GuardSpec:
    """One class's concurrency contract: ``attrs`` change only under
    ``getattr(self, lock_attr)``."""

    module: str      # import path, e.g. "repro.serving.server"
    cls_name: str    # class whose instances carry the state
    lock_attr: str   # attribute holding the guard lock
    attrs: Tuple[str, ...]  # attributes the lock guards


# The serving layer's shared state, one spec per class.  Mirrors the
# docstring contracts of each class; LCK002 and the dynamic checker both
# read this, so adding an attribute here immediately puts it under both
# static and runtime enforcement.
REGISTRY: Tuple[GuardSpec, ...] = (
    GuardSpec(
        "repro.serving.server", "PlanServer", "_lock",
        (
            # miss coalescing, background dedup, upgrade tracking
            "_inflight", "_background_keys", "_inexact", "_prewarmed",
            # worker accounting + lifecycle flags
            "_busy", "_running", "_closed",
            # fabric-event state
            "_active_topo", "_fabric_version", "_family_alias",
        ),
        # Unregistered by design: _dying (keyed by thread ident; each
        # entry is written only by its own thread), _threads (mutated in
        # start/stop only, before workers exist / after they joined).
    ),
    GuardSpec(
        "repro.serving.queue", "TieredQueue", "_lock",
        ("_count", "_closed", "_tiers"),
    ),
    GuardSpec(
        "repro.serving.telemetry", "Telemetry", "_lock",
        (
            "_counters", "_latency",
            "_synth_hist", "_synth_count", "_synth_sum",
            "_repair_hist", "_repair_count", "_repair_sum",
            "_queue_depth", "_queue_peak",
            "_fabric_version", "_fabric_events", "_fabric_last",
        ),
    ),
    GuardSpec(
        "repro.serving.policy", "TTLPolicy", "_lock",
        ("_born",),
    ),
    GuardSpec(
        "repro.serving.policy", "DriftPredictor", "_lock",
        ("_families",),
    ),
    GuardSpec(
        "repro.serving.events", "FabricMonitor", "_lock",
        ("_topology", "_version", "_subscribers", "_history"),
    ),
    GuardSpec(
        "repro.core.plan", "PlanCache", "_lock",
        ("_store", "_family", "_key_family", "_family_count",
         "hits", "misses", "warm_hits"),
    ),
)


def specs_by_class() -> Dict[str, GuardSpec]:
    """Registry indexed by class name (what the AST lint keys on)."""
    return {spec.cls_name: spec for spec in REGISTRY}


class GuardViolation(NamedTuple):
    cls_name: str
    attr: str
    lock_attr: str
    thread: str
    detail: str


_state_lock = threading.Lock()  # noqa: LCK001 -- the checker's own lock
_violations: List[GuardViolation] = []
_installed: Dict[str, Tuple[type, object, object]] = {}
_ARMED_FLAG = "_repro_guards_armed"


def guard_violations() -> List[GuardViolation]:
    with _state_lock:
        return list(_violations)


def reset_violations() -> None:
    with _state_lock:
        del _violations[:]


def installed() -> bool:
    return bool(_installed)


def _record(spec: GuardSpec, attr: str) -> None:
    v = GuardViolation(
        cls_name=spec.cls_name, attr=attr, lock_attr=spec.lock_attr,
        thread=threading.current_thread().name,
        detail=(f"{spec.cls_name}.{attr} written without holding "
                f"{spec.cls_name}.{spec.lock_attr} "
                f"(thread {threading.current_thread().name!r})"),
    )
    with _state_lock:
        _violations.append(v)


def install() -> int:
    """Wrap every registered class for dynamic guarded-write checking.

    Returns the number of classes instrumented.  Idempotent; undone by
    ``uninstall``.  Only instances constructed *after* install are
    checked (the wrapper arms per-instance at constructor exit), and only
    writes where the guard lock is a tracked lock are judged -- plain
    locks carry no ownership information.
    """
    for spec in REGISTRY:
        key = f"{spec.module}.{spec.cls_name}"
        if key in _installed:
            continue
        cls = getattr(importlib.import_module(spec.module), spec.cls_name)
        orig_init = cls.__init__
        orig_setattr = cls.__setattr__
        guarded = frozenset(spec.attrs)

        def wrapped_init(self, *args, _orig=orig_init, **kwargs):
            _orig(self, *args, **kwargs)
            object.__setattr__(self, _ARMED_FLAG, True)

        def wrapped_setattr(self, name, value, _orig=orig_setattr,
                            _spec=spec, _guarded=guarded):
            if name in _guarded and getattr(self, _ARMED_FLAG, False):
                lock = getattr(self, _spec.lock_attr, None)
                held = getattr(lock, "held_by_current_thread", None)
                if held is not None and not held():
                    _record(_spec, name)
            _orig(self, name, value)

        functools.update_wrapper(wrapped_init, orig_init)
        cls.__init__ = wrapped_init
        cls.__setattr__ = wrapped_setattr
        _installed[key] = (cls, orig_init, orig_setattr)
    return len(_installed)


def uninstall() -> None:
    """Restore every class ``install`` wrapped."""
    for cls, orig_init, orig_setattr in _installed.values():
        cls.__init__ = orig_init
        cls.__setattr__ = orig_setattr
    _installed.clear()


def report() -> Dict:
    """JSON-compatible summary for the analysis runner."""
    return {
        "classes": [
            {"class": s.cls_name, "module": s.module, "lock": s.lock_attr,
             "attrs": list(s.attrs)}
            for s in REGISTRY
        ],
        "installed": installed(),
        "violations": [v._asdict() for v in guard_violations()],
    }
