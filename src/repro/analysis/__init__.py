"""Static-analysis and concurrency-contract subsystem.

Four coordinated passes keep the scheduler's structural claims -- and the
serving daemon's thread-safety invariants -- machine-checked instead of
re-argued in every review (DESIGN.md, "Static analysis & concurrency
contracts"):

  * ``analysis.locks``    -- named tracked locks, the process-global
    lock-order graph (cycle = potential deadlock), and
    forbidden-while-held contracts (no synthesis under a serving lock).
  * ``analysis.guards``   -- the guarded-state registry: which shared
    attributes which lock protects, with a dynamic assert-on-write mode.
  * ``analysis.astlint``  -- custom AST lint (LCK001 raw locks, LCK002
    unguarded writes, EXC001 swallowed broad excepts, DET001
    nondeterminism in core/).
  * ``analysis.planlint`` -- the workload-independent plan verifier:
    incast-freedom, self-traffic, slot feasibility, stage ordering and
    topology consistency on serialized Plan JSON and live cache contents.

Run everything with ``python -m repro.analysis --all`` (CI-gated).

This ``__init__`` deliberately imports only the dependency-free runtime
modules: ``core``/``serving`` import the lock factories from here, so
pulling in ``planlint`` (which imports ``core.plan``) at package import
time would be a cycle.
"""

from . import guards, locks  # noqa: F401  (re-exported submodules)

__all__ = ["locks", "guards"]
