"""Workload-independent plan verifier.

``Plan.validate(w)`` proves byte conservation *against a workload* -- but
a serialized plan corpus or a live ``PlanCache`` has no workloads
attached, only plans.  This pass checks every invariant a plan must
satisfy on its own:

  * **PLAN-STRUCT** -- everything ``Plan.validate_structure`` proves:
    permutation stages are incast-free and self-traffic-free, payloads
    fit their per-sender slots, blocks are shape-consistent, and
    capacity-aware plans are slot-vs-rail feasible on their own fabric.
  * **PLAN-SHAPE** -- the plan's topology agrees with its cluster view
    (server/GPU counts) and every permutation is n_servers wide.
  * **PLAN-ORDER** -- consecutive cold ``PermutationStage`` phases run in
    ascending duration order (the Theorem-2 pipelining contract:
    synthesis sorts stages so each stage's redistribute hides under the
    *next* stage's transfer).  ``PermutationBlock`` phases are exempt --
    incremental repair deliberately emits stages in stored order.
  * **PLAN-FPRINT** -- serialization round-trip stability: rebuilding the
    plan from ``to_dict()`` must preserve the topology fingerprint (a
    drifting fingerprint would turn every cache hit cold after a
    save/load cycle).
  * **CACHE-FAMILY** (audit mode) -- each cached family head actually
    belongs to the family key it is indexed under, so warm-start lookups
    can never seed a repair from a different fabric's plan.

``audit_cache`` runs the whole battery over ``PlanCache.family_heads()``;
``PlanServer.audit()`` exposes it on the live daemon.
"""

from __future__ import annotations

import json
import os
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..core.birkhoff import stage_duration
from ..core.plan import (
    PermutationStage,
    Plan,
    PlanValidationError,
    plan_family_key,
)

__all__ = ["check_plan", "check_file", "check_paths", "audit_cache"]

# Slack factor for the ascending-duration check; synthesis sorts stages
# by exact duration, so anything beyond float noise is a real inversion.
_ORDER_RTOL = 1e-9


def _issue(code: str, message: str, source: str) -> Dict:
    return {"code": code, "message": message, "source": source}


def check_plan(plan: Plan, source: str = "<plan>") -> List[Dict]:
    """Every workload-independent defect of one plan (empty = clean)."""
    issues: List[Dict] = []

    try:
        plan.validate_structure()
    except PlanValidationError as e:
        issues.append(_issue("PLAN-STRUCT", str(e), source))

    topo = plan.topo
    n = plan.cluster.n_servers
    if (topo.n_servers, topo.m_gpus) != (n, plan.cluster.m_gpus):
        issues.append(_issue(
            "PLAN-SHAPE",
            f"topology is {topo.n_servers}x{topo.m_gpus} but the cluster "
            f"view says {n}x{plan.cluster.m_gpus}", source))
    for k, p in enumerate(plan.phases):
        if isinstance(p, PermutationStage) and len(p.perm) != n:
            issues.append(_issue(
                "PLAN-SHAPE",
                f"stage {k} permutation is {len(p.perm)} wide on an "
                f"{n}-server cluster", source))

    issues.extend(_check_stage_order(plan, source))

    try:
        rebuilt = Plan.from_dict(plan.to_dict())
    except PlanValidationError as e:
        issues.append(_issue(
            "PLAN-FPRINT", f"plan does not round-trip: {e}", source))
    else:
        if rebuilt.topo.fingerprint() != topo.fingerprint():
            issues.append(_issue(
                "PLAN-FPRINT",
                "topology fingerprint drifts across a to_dict/from_dict "
                "round trip; cached plans would go cold after save/load",
                source))
    return issues


def _check_stage_order(plan: Plan, source: str) -> List[Dict]:
    """Ascending order over runs of consecutive cold stages.

    Synthesis sorts by the quantity its decomposition actually ranks:
    capacity-aware plans by per-stage *duration* on their own fabric,
    capacity-blind plans by slot *size* (duration's proxy under the
    uniform-capacity assumption they were built with -- on a degraded
    fabric a blind plan's durations legitimately interleave).
    """
    caps = plan.topo.pair_capacity() if plan.capacity_aware else None
    unit = "s" if plan.capacity_aware else " bytes"
    issues: List[Dict] = []
    prev: Optional[float] = None
    prev_k = -1
    for k, p in enumerate(plan.phases):
        if not isinstance(p, PermutationStage):
            prev = None
            continue
        key = (stage_duration(p, caps) if caps is not None
               else float(p.size))
        if prev is not None and np.isfinite(prev) and np.isfinite(key) \
                and key < prev * (1 - _ORDER_RTOL):
            issues.append(_issue(
                "PLAN-ORDER",
                f"stage {k} ({key:.6g}{unit}) runs before-sorted stage "
                f"{prev_k} ({prev:.6g}{unit}): cold permutation stages "
                "must ascend so redistributes pipeline (Theorem 2)",
                source))
        prev, prev_k = key, k
    return issues


def check_file(path: str) -> List[Dict]:
    """Verify one JSON file holding a plan dict or a list of them."""
    try:
        with open(path, "r") as f:
            data = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [_issue("PLAN-IO", f"unreadable plan file: {e}", path)]
    plans = data if isinstance(data, list) else [data]
    issues: List[Dict] = []
    for i, d in enumerate(plans):
        src = f"{path}[{i}]" if isinstance(data, list) else path
        try:
            plan = Plan.from_dict(d)
        except (PlanValidationError, KeyError, TypeError, ValueError) as e:
            issues.append(_issue(
                "PLAN-IO", f"undeserializable plan: {e}", src))
            continue
        issues.extend(check_plan(plan, src))
    return issues


def check_paths(paths: Sequence[str]) -> Dict:
    """Verify a corpus of plan JSON files; directories are walked for
    ``*.json``.  Returns ``{"plans": n, "files": n, "issues": [...]}``."""
    files: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            for name in sorted(os.listdir(p)):
                if name.endswith(".json"):
                    files.append(os.path.join(p, name))
        else:
            files.append(p)
    issues: List[Dict] = []
    plans = 0
    for path in files:
        try:
            with open(path, "r") as f:
                data = json.load(f)
            plans += len(data) if isinstance(data, list) else 1
        except (OSError, json.JSONDecodeError):
            plans += 1  # counted; check_file reports the IO issue
        issues.extend(check_file(path))
    return {"files": len(files), "plans": plans, "issues": issues,
            "clean": not issues}


def audit_cache(cache) -> Dict:
    """Verify every family head of a live ``PlanCache``.

    Beyond the per-plan battery, proves the family index itself: the plan
    stored under family key F must re-derive F from its own cluster,
    topology and algorithm -- a mismatch means warm-start would seed
    repairs from the wrong fabric's plan.
    """
    heads = cache.family_heads()
    issues: List[Dict] = []
    for family, plan in heads:
        source = f"cache:{family[:12]}"
        issues.extend(check_plan(plan, source))
        derived = plan_family_key(plan)
        if derived != family:
            issues.append(_issue(
                "CACHE-FAMILY",
                f"plan indexed under family {family[:12]}... but derives "
                f"{derived[:12]}... from its own cluster/topology/"
                "algorithm", source))
    return {"plans": len(heads), "issues": issues, "clean": not issues}
