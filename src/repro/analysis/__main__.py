"""CI gate: ``python -m repro.analysis --all``.

Runs the static passes and exits nonzero on any finding:

  * ``--astlint``  -- the LCK001/LCK002/EXC001/DET001 rules over every
    ``core/`` and ``serving/`` module (analysis/astlint.py).
  * ``--planlint`` -- the workload-independent plan verifier over a
    golden plan corpus (analysis/planlint.py).  ``--corpus DIR`` points
    at an existing corpus (e.g. one emitted by
    ``python -m benchmarks.emit_corpus``); without it, a fresh corpus is
    synthesized into a temporary directory first.
  * ``--all``      -- both.

``--json PATH`` additionally writes the full machine-readable report
(uploaded as a CI artifact alongside the benchmark JSON).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile

from . import astlint, planlint

ANALYSIS_SCHEMA_VERSION = 1


def _src_root() -> str:
    """The directory containing the ``repro`` package."""
    pkg_dir = os.path.dirname(os.path.abspath(__file__))  # .../repro/analysis
    return os.path.dirname(os.path.dirname(pkg_dir))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static concurrency & plan-IR analysis gate.")
    ap.add_argument("--astlint", action="store_true",
                    help="run the AST rules over core/, comm/ and serving/")
    ap.add_argument("--planlint", action="store_true",
                    help="verify a plan corpus (see --corpus)")
    ap.add_argument("--all", action="store_true",
                    help="every pass (what CI runs)")
    ap.add_argument("--corpus", default=None, metavar="DIR",
                    help="plan-corpus directory for --planlint; "
                    "synthesized fresh into a temp dir when omitted")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the machine-readable report here")
    args = ap.parse_args(argv)

    run_ast = args.astlint or args.all
    run_plan = args.planlint or args.all
    if not (run_ast or run_plan):
        ap.error("pick at least one of --astlint / --planlint / --all")

    report = {"schema": ANALYSIS_SCHEMA_VERSION, "passes": {}}
    failed = False

    if run_ast:
        findings = astlint.lint_tree(_src_root())
        report["passes"]["astlint"] = {
            "findings": [f.to_dict() for f in findings],
            "clean": not findings,
        }
        for f in findings:
            print(f.format())
        print(f"astlint: {len(findings)} finding(s) over core/, "
              "comm/ and serving/")
        failed = failed or bool(findings)

    if run_plan:
        tmp = None
        corpus_dir = args.corpus
        if corpus_dir is None:
            from . import corpus as corpus_mod
            tmp = tempfile.TemporaryDirectory(prefix="plan_corpus_")
            corpus_dir = tmp.name
            print(f"planlint: synthesizing golden corpus in {corpus_dir}")
            corpus_mod.emit_corpus(corpus_dir)
        result = planlint.check_paths([corpus_dir])
        report["passes"]["planlint"] = result
        for issue in result["issues"]:
            print(f"{issue['source']}: {issue['code']} "
                  f"{issue['message']}")
        print(f"planlint: {result['plans']} plan(s) in "
              f"{result['files']} file(s), "
              f"{len(result['issues'])} issue(s)")
        failed = failed or not result["clean"]
        if tmp is not None:
            tmp.cleanup()

    report["clean"] = not failed
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        print(f"report written to {args.json}")

    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
