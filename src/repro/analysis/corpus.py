"""Golden plan corpus for the workload-independent verifier.

``emit_corpus`` synthesizes a small, deterministic battery of plans --
every registered scheduler crossed with uniform / random / skewed / MoE
traffic on homogeneous and degraded fabrics -- and serializes each to
JSON.  The CI analysis gate (``python -m repro.analysis --all``) then
runs ``planlint.check_paths`` over the emitted files: any scheduler
change that starts producing structurally invalid plans (incast,
slot overflow, unsorted cold stages, fingerprint drift) fails the gate
even if no unit test exercises that exact configuration.

Seeds and shapes are fixed so the corpus is reproducible; the
``benchmarks/emit_corpus.py`` wrapper exposes this as a benchmark-suite
entry point.
"""

from __future__ import annotations

import json
import os
from typing import Dict, List

from ..core.schedulers import SCHEDULERS, get_scheduler
from ..core.topology import Topology
from ..core.traffic import (
    ClusterSpec,
    Workload,
    balanced_workload,
    moe_workload,
    random_workload,
    skewed_workload,
)

__all__ = ["corpus_workloads", "emit_corpus"]

_MB = 1e6


def corpus_workloads() -> List[Dict]:
    """The named workload battery: ``{"name", "workload"}`` entries."""
    small = ClusterSpec(n_servers=4, m_gpus=2)
    mid = ClusterSpec(n_servers=8, m_gpus=4)
    entries = [
        {"name": "uniform_n4", "workload": balanced_workload(small, _MB)},
        {"name": "random_n8",
         "workload": random_workload(mid, _MB, seed=7)},
        {"name": "skewed_n8",
         "workload": skewed_workload(mid, _MB, zipf_s=1.4, seed=11)},
        {"name": "moe_n8",
         "workload": moe_workload(mid, tokens_per_gpu=512,
                                  bytes_per_token=2048, seed=3)},
    ]
    # A degraded fabric: one NIC at 30 percent -- the capacity-aware
    # schedulers must stay slot-vs-rail feasible here, not just on the
    # homogeneous happy path.
    degraded = Topology.from_cluster(mid).degrade_nic(2, 1, 0.3, "both")
    w = random_workload(mid, _MB, seed=19)
    entries.append({"name": "degraded_n8",
                    "workload": Workload(w.cluster, w.matrix, degraded)})
    return entries


def emit_corpus(out_dir: str, algorithms: List[str] = None) -> List[str]:
    """Synthesize and serialize the corpus; returns written file paths.

    One JSON file per workload, each holding a list of plan dicts (one
    per scheduler) -- the layout ``planlint.check_paths`` consumes.
    """
    os.makedirs(out_dir, exist_ok=True)
    algos = sorted(SCHEDULERS) if algorithms is None else algorithms
    written: List[str] = []
    for entry in corpus_workloads():
        plans = []
        for algo in algos:
            plan = get_scheduler(algo).synthesize(entry["workload"])
            plans.append(plan.to_dict())
        path = os.path.join(out_dir, f"{entry['name']}.json")
        with open(path, "w") as f:
            json.dump(plans, f, indent=1, sort_keys=True)
        written.append(path)
    return written
