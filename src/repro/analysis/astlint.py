"""AST lint for the repo's concurrency and determinism contracts.

Four rules, each encoding an invariant the test suite cannot cheaply
enforce at runtime:

  * **LCK001** -- a raw ``threading.Lock()`` / ``RLock()`` /
    ``Condition()`` constructed inside ``serving/`` or ``core/``.  Every
    lock there must come from the tracked factories in
    ``analysis/locks.py`` (``make_lock`` / ``make_rlock`` /
    ``make_condition``) so the lock-order graph and the
    forbidden-while-held contracts see it.  ``threading.Event`` and
    friends are fine -- only the three lockable primitives participate
    in ordering.

  * **LCK002** -- a write to a guarded shared attribute (registered in
    ``analysis/guards.py``) outside a ``with self.<lock>`` block.  Writes
    cover plain/augmented assignment, subscript stores and deletes, and
    calls to known container mutators (``append``, ``pop``, ``update``,
    ...).  Methods whose name ends in ``_locked`` assert "caller holds
    the lock" by convention and are exempt, as is ``__init__`` (the
    object is not yet shared).

  * **EXC001** -- an ``except Exception`` / ``except BaseException`` /
    bare ``except`` whose body neither re-raises, nor increments a
    telemetry counter (a ``.count(...)`` call), nor captures the
    exception object into an outer variable (the ``err = e`` respawn
    pattern).  Swallowing without any of those hides operational errors.

  * **DET001** -- a nondeterminism source in ``core/`` or ``comm/``:
    ``time.time()`` (wall clock; ``perf_counter``/``monotonic`` are fine
    and intended) or unseeded ``np.random`` access (anything except
    ``np.random.default_rng(seed)`` / ``np.random.Generator``).  Core
    synthesis must be a pure function of its inputs so plans replay
    bit-identically -- and the comm layer's plan lowering
    (``comm/plan_exec.py``) bakes those plans into traced programs, so
    the same determinism contract extends to it.

Suppression: append ``# noqa: LCK001`` (or the relevant rule id, comma
separated) to the offending line.  A bare ``# noqa`` silences every rule
on that line, matching the flake8 convention.
"""

from __future__ import annotations

import ast
import os
import re
from typing import Dict, List, NamedTuple, Optional, Sequence, Set, Tuple

from . import guards

__all__ = ["Finding", "lint_source", "lint_file", "lint_paths",
           "lint_tree", "RULES"]

RULES = ("LCK001", "LCK002", "EXC001", "DET001")

# Container mutators that modify a guarded attribute in place; calling one
# outside the guard lock is as racy as assigning to the attribute.
_MUTATORS = frozenset({
    "add", "append", "appendleft", "clear", "discard", "extend",
    "insert", "move_to_end", "pop", "popitem", "popleft", "remove",
    "setdefault", "update",
})

_LOCK_CTORS = frozenset({"Lock", "RLock", "Condition"})

_NOQA_RE = re.compile(r"#\s*noqa(?::\s*(?P<rules>[A-Z0-9, ]+))?",
                      re.IGNORECASE)


class Finding(NamedTuple):
    rule: str
    path: str
    line: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"

    def to_dict(self) -> Dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "message": self.message}


def _noqa_rules(source_line: str) -> Optional[Set[str]]:
    """The rule ids a ``# noqa`` comment on this line silences, the empty
    set for a bare ``# noqa`` (silence everything), None when absent."""
    m = _NOQA_RE.search(source_line)
    if m is None:
        return None
    rules = m.group("rules")
    if rules is None:
        return set()
    return {r.strip().upper() for r in rules.split(",") if r.strip()}


def _suppressed(lines: Sequence[str], lineno: int, rule: str) -> bool:
    if not 1 <= lineno <= len(lines):
        return False
    rules = _noqa_rules(lines[lineno - 1])
    if rules is None:
        return False
    return not rules or rule in rules


def _is_self_attr(node: ast.AST, attrs: frozenset) -> Optional[str]:
    """The attribute name when ``node`` is ``self.<attr>`` with ``attr``
    in ``attrs``, else None."""
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"
            and node.attr in attrs):
        return node.attr
    return None


def _withitem_locks(stmt: ast.With) -> Set[str]:
    """Attribute names of every ``self.<attr>`` context manager in a
    ``with`` statement (``with self._lock:`` -> {"_lock"})."""
    out: Set[str] = set()
    for item in stmt.items:
        ctx = item.context_expr
        if (isinstance(ctx, ast.Attribute)
                and isinstance(ctx.value, ast.Name)
                and ctx.value.id == "self"):
            out.add(ctx.attr)
    return out


class _Linter(ast.NodeVisitor):
    def __init__(self, path: str, lines: Sequence[str],
                 guard_specs: Dict[str, Tuple[str, frozenset]],
                 check_lck001: bool, check_det001: bool):
        self.path = path
        self.lines = lines
        self.guard_specs = guard_specs  # class name -> (lock_attr, attrs)
        self.check_lck001 = check_lck001
        self.check_det001 = check_det001
        self.findings: List[Finding] = []
        # LCK002 state, valid only while walking a guarded class body.
        self._guard: Optional[Tuple[str, frozenset]] = None
        self._held: List[str] = []  # stack of with-held self.<attr> names
        self._exempt_method = False

    def _emit(self, rule: str, node: ast.AST, message: str) -> None:
        if _suppressed(self.lines, node.lineno, rule):
            return
        self.findings.append(Finding(rule, self.path, node.lineno, message))

    # -- LCK001 -----------------------------------------------------------

    def visit_Call(self, node: ast.Call) -> None:
        if self.check_lck001:
            fn = node.func
            name = None
            if (isinstance(fn, ast.Attribute)
                    and isinstance(fn.value, ast.Name)
                    and fn.value.id == "threading"):
                name = fn.attr
            elif isinstance(fn, ast.Name):
                name = fn.id if fn.id in _LOCK_CTORS else None
            if name in _LOCK_CTORS:
                self._emit(
                    "LCK001", node,
                    f"raw threading.{name}() -- use the tracked factory "
                    f"make_{'condition' if name == 'Condition' else name.lower()}"  # noqa: E501
                    "(name) from repro.analysis.locks so the lock "
                    "participates in lock-order analysis")
        if self._lck002_active():
            self._check_mutator_call(node)
        if self.check_det001:
            self._check_det001_call(node)
        self.generic_visit(node)

    # -- DET001 -----------------------------------------------------------

    def _check_det001_call(self, node: ast.Call) -> None:
        fn = node.func
        # time.time()
        if (isinstance(fn, ast.Attribute) and fn.attr == "time"
                and isinstance(fn.value, ast.Name)
                and fn.value.id == "time"):
            self._emit("DET001", node,
                       "wall-clock time.time() in core/ -- use "
                       "time.perf_counter() (interval) or take the "
                       "timestamp as a parameter")
        # np.random.<anything but default_rng/Generator>
        if (isinstance(fn, ast.Attribute)
                and isinstance(fn.value, ast.Attribute)
                and fn.value.attr == "random"
                and isinstance(fn.value.value, ast.Name)
                and fn.value.value.id in ("np", "numpy")):
            if fn.attr not in ("default_rng", "Generator"):
                self._emit("DET001", node,
                           f"np.random.{fn.attr}() uses the unseeded "
                           "global RNG in core/ -- thread an explicit "
                           "np.random.default_rng(seed) through instead")

    # -- EXC001 -----------------------------------------------------------

    def visit_ExceptHandler(self, node: ast.ExceptHandler) -> None:
        broad = node.type is None or (
            isinstance(node.type, ast.Name)
            and node.type.id in ("Exception", "BaseException"))
        if broad and not self._exc_body_ok(node):
            caught = (node.type.id if isinstance(node.type, ast.Name)
                      else "everything")
            self._emit(
                "EXC001", node,
                f"broad except {caught} swallows the error: re-raise, "
                "count it in telemetry, or capture the exception for a "
                "later re-raise")
        self.generic_visit(node)

    @staticmethod
    def _exc_body_ok(node: ast.ExceptHandler) -> bool:
        captured = node.name  # `except Exception as e` -> "e"
        for stmt in ast.walk(ast.Module(body=node.body,
                                        type_ignores=[])):
            if isinstance(stmt, ast.Raise):
                return True
            if (isinstance(stmt, ast.Call)
                    and isinstance(stmt.func, ast.Attribute)
                    and stmt.func.attr == "count"):
                return True
            if (captured and isinstance(stmt, ast.Assign)
                    and isinstance(stmt.value, ast.Name)
                    and stmt.value.id == captured):
                return True
        return False

    # -- LCK002 -----------------------------------------------------------

    def _lck002_active(self) -> bool:
        return (self._guard is not None and not self._exempt_method
                and self._guard[0] not in self._held)

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        prev = self._guard
        self._guard = self.guard_specs.get(node.name)
        self.generic_visit(node)
        self._guard = prev

    def _visit_function(self, node) -> None:
        prev = self._exempt_method
        self._exempt_method = (node.name == "__init__"
                               or node.name.endswith("_locked"))
        self.generic_visit(node)
        self._exempt_method = prev

    visit_FunctionDef = _visit_function
    visit_AsyncFunctionDef = _visit_function

    def visit_With(self, node: ast.With) -> None:
        held = _withitem_locks(node)
        self._held.extend(held)
        self.generic_visit(node)
        del self._held[len(self._held) - len(held):]

    def _guarded_attr(self, node: ast.AST) -> Optional[str]:
        """The guarded attribute a store-target touches, if any: plain
        ``self.attr``, ``self.attr[k]`` stores, and their Starred/Tuple
        unpacking forms."""
        if self._guard is None:
            return None
        _, attrs = self._guard
        if isinstance(node, (ast.Tuple, ast.List)):
            for elt in node.elts:
                hit = self._guarded_attr(elt)
                if hit:
                    return hit
            return None
        if isinstance(node, ast.Starred):
            return self._guarded_attr(node.value)
        if isinstance(node, ast.Subscript):
            return self._guarded_attr(node.value)
        return _is_self_attr(node, attrs)

    def _emit_lck002(self, node: ast.AST, attr: str, what: str) -> None:
        lock_attr = self._guard[0]
        self._emit(
            "LCK002", node,
            f"{what} guarded attribute self.{attr} outside "
            f"`with self.{lock_attr}` (rename the method *_locked if the "
            "caller provably holds the lock)")

    def visit_Assign(self, node: ast.Assign) -> None:
        if self._lck002_active():
            for tgt in node.targets:
                attr = self._guarded_attr(tgt)
                if attr:
                    self._emit_lck002(node, attr, "write to")
                    break
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        if self._lck002_active():
            attr = self._guarded_attr(node.target)
            if attr:
                self._emit_lck002(node, attr, "augmented write to")
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        if self._lck002_active() and node.value is not None:
            attr = self._guarded_attr(node.target)
            if attr:
                self._emit_lck002(node, attr, "write to")
        self.generic_visit(node)

    def visit_Delete(self, node: ast.Delete) -> None:
        if self._lck002_active():
            for tgt in node.targets:
                attr = self._guarded_attr(tgt)
                if attr:
                    self._emit_lck002(node, attr, "delete on")
                    break
        self.generic_visit(node)

    def _check_mutator_call(self, node: ast.Call) -> None:
        fn = node.func
        if not (isinstance(fn, ast.Attribute) and fn.attr in _MUTATORS):
            return
        _, attrs = self._guard
        attr = _is_self_attr(fn.value, attrs)
        if attr is None and isinstance(fn.value, ast.Subscript):
            attr = _is_self_attr(fn.value.value, attrs)
        if attr:
            self._emit_lck002(node, attr, f".{fn.attr}() on")


def _guard_specs_for_module(rel_module: str
                            ) -> Dict[str, Tuple[str, frozenset]]:
    """LCK002 specs applicable to one module, keyed by class name."""
    out: Dict[str, Tuple[str, frozenset]] = {}
    for spec in guards.REGISTRY:
        if spec.module == rel_module:
            out[spec.cls_name] = (spec.lock_attr, frozenset(spec.attrs))
    return out


def _module_name(path: str, root: str) -> str:
    """Dotted module path of ``path`` relative to the src root, e.g.
    ``.../src/repro/serving/server.py`` -> ``repro.serving.server``."""
    rel = os.path.relpath(os.path.abspath(path), os.path.abspath(root))
    rel = rel[:-3] if rel.endswith(".py") else rel
    return rel.replace(os.sep, ".")


def lint_source(source: str, path: str = "<string>", *,
                module: str = "",
                check_lck001: bool = True,
                check_det001: bool = False,
                guard_specs: Optional[Dict] = None) -> List[Finding]:
    """Lint one module's source text; the testable core of the pass."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding("EXC001", path, e.lineno or 0,
                        f"unparseable module: {e.msg}")]
    lines = source.splitlines()
    specs = (guard_specs if guard_specs is not None
             else _guard_specs_for_module(module))
    linter = _Linter(path, lines, specs, check_lck001, check_det001)
    linter.visit(tree)
    return sorted(linter.findings, key=lambda f: (f.line, f.rule))


def lint_file(path: str, src_root: str) -> List[Finding]:
    module = _module_name(path, src_root)
    parts = module.split(".")
    # DET001 (replay determinism) covers synthesis (core/) and the plan
    # lowering that bakes plans into traced programs (comm/).
    check_det = "core" in parts or "comm" in parts
    in_scope = check_det or "serving" in parts
    if not in_scope:
        return []
    with open(path, "r") as f:
        source = f.read()
    return lint_source(source, path, module=module,
                       check_lck001=True, check_det001=check_det)


def lint_paths(paths: Sequence[str], src_root: str) -> List[Finding]:
    findings: List[Finding] = []
    for path in paths:
        findings.extend(lint_file(path, src_root))
    return findings


def lint_tree(src_root: str) -> List[Finding]:
    """Lint every ``core/``, ``comm/`` and ``serving/`` module under
    ``src_root`` (the directory containing the ``repro`` package)."""
    paths = []
    for sub in ("repro/core", "repro/comm", "repro/serving"):
        d = os.path.join(src_root, sub)
        if not os.path.isdir(d):
            continue
        for name in sorted(os.listdir(d)):
            if name.endswith(".py"):
                paths.append(os.path.join(d, name))
    return lint_paths(paths, src_root)
